"""LM train/decode throughput on smoke configs: the paper's method ladder
applied to transformer-family models (its 'future work' — transformers —
is our assigned zoo)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.launch import steps
from repro.launch.mesh import make_host_mesh

ARCHS = ("smollm-360m", "olmoe-1b-7b", "xlstm-350m")
METHODS = {
    "org": dict(enabled=False),
    "lrd": dict(enabled=True, rank_quantize=False),
    "combined": dict(enabled=True, rank_quantize=False, freeze_mode="sequential"),
}


def run(seq=64, batch=4, iters=3):
    rows = []
    mesh = make_host_mesh(1, 1)
    for arch in ARCHS:
        base_fps = None
        for method, lrd_kw in METHODS.items():
            cfg = get_smoke_config(arch)
            run_cfg = RunConfig(
                model=cfg, shape=ShapeConfig("b", seq, batch, "train"),
                lrd=LRDConfig(min_dim=16, **lrd_kw),
                dist=DistConfig(fsdp=False, remat="none"),
                optim=OptimConfig(name="sgdm", lr=1e-3, warmup_steps=0,
                                  total_steps=100))
            params, _ = steps.init_params(run_cfg, jax.random.PRNGKey(0))
            phase = 0 if lrd_kw.get("freeze_mode") else -1
            state, _ = steps.make_train_state(run_cfg.optim, params, phase)
            fn = jax.jit(functools.partial(steps.build_train_step(run_cfg, mesh),
                                           phase=phase))
            key = jax.random.PRNGKey(1)
            batch_d = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
                       "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)}
            if cfg.family == "encdec":
                batch_d["frames"] = jnp.zeros((batch, cfg.encoder_frames, cfg.d_model),
                                              cfg.cdtype)
            t = time_fn(lambda: fn(state, batch_d), iters=iters)
            fps = batch * seq / t
            if base_fps is None:
                base_fps = fps
            rows.append({"arch": arch, "method": method, "tok_per_s": fps,
                         "delta_pct": 100 * (fps / base_fps - 1)})
    return rows


def main(**kw):
    rows = run(**kw)
    print("# LM train throughput: arch/method, tokens_per_s, delta%")
    for r in rows:
        print(f"{r['arch']}/{r['method']},{r['tok_per_s']:.0f},"
              f"{r['delta_pct']:+.1f}%")
    return rows


if __name__ == "__main__":
    main()
