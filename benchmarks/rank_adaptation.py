"""In-training rank adaptation bench (DESIGN.md §10): per-phase step time,
live trainable-partition bytes, and per-step collective sync bytes as a
decaying rank schedule truncates factor groups at each Algorithm-2 phase
boundary, against a fixed-rank baseline on the smoke LM.

Both variants consume the SAME synthetic data stream; each epoch is one
measurement segment (the schedule fires at the epoch boundary, so ranks are
constant within a segment).  Bytes are measured on the LIVE concrete state
(params of the trainable partition + grads + optimizer moments) — the thing
the paper's training-memory claim is about; sync bytes come from the
compiled step's post-SPMD HLO (zero on one device, real on the CI 8-device
host mesh).

Smoke acceptance (wired into run.py --smoke and ci.yml): under the decay
schedule the trainable-partition bytes must STRICTLY decrease at every
boundary, and the final-epoch mean loss must stay within 2% of the
fixed-rank baseline.

  PYTHONPATH=src python -m benchmarks.rank_adaptation --smoke
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import record
from repro.analysis.hlo import analyze_hlo
from repro.configs import get_smoke_config
from repro.configs.base import (DistConfig, LRDConfig, OptimConfig, RunConfig,
                                ShapeConfig)
from repro.core import rank_adapt
from repro.data import LMBatchIterator
from repro.launch import steps
from repro.launch.mesh import make_host_mesh

ARCH = "smollm-360m"
_SYNC_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all")


def _bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def _partition_bytes(state) -> int:
    """Live trainable-partition bytes: params + grads (accum dtype = fp32
    here) + optimizer moments — the per-device training-memory quantity the
    rank schedule shrinks."""
    params_b = _bytes(state.trainable)
    grads_b = sum(x.size * 4 for x in jax.tree_util.tree_leaves(state.trainable))
    opt_b = _bytes(state.opt.mu) + (_bytes(state.opt.nu)
                                    if state.opt.nu != () else 0)
    return params_b + grads_b + opt_b


def _sync_bytes(jitted, state, batch, mesh) -> int:
    if mesh.devices.size <= 1:
        return 0
    txt = jitted.lower(state, batch).compile().as_text()
    cb = analyze_hlo(txt).collective_bytes
    return int(sum(v for k, v in cb.items() if k in _SYNC_OPS))


def _build_run(rank_schedule: str, decay: float, seq: int, batch: int,
               total_steps: int) -> RunConfig:
    return RunConfig(
        model=get_smoke_config(ARCH),
        shape=ShapeConfig("b", seq, batch, "train"),
        lrd=LRDConfig(enabled=True, min_dim=16, rank_quantize=False,
                      freeze_mode="sequential", rank_schedule=rank_schedule,
                      rank_decay=decay, rank_min=2),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="adamw", lr=1e-3, warmup_steps=0,
                          total_steps=total_steps, schedule="constant"),
    )


def _train_variant(variant: str, run_cfg: RunConfig, mesh, epochs: int,
                   steps_per_epoch: int, seed: int):
    schedule = rank_adapt.schedule_from_config(run_cfg.lrd)
    params, _ = steps.init_params(run_cfg, jax.random.PRNGKey(seed))
    state, parked = steps.make_sharded_train_state(run_cfg, params, 0, mesh)
    train = steps.build_train_step(run_cfg, mesh)
    data = iter(LMBatchIterator(run_cfg.model.vocab_size, run_cfg.shape.seq_len,
                                run_cfg.shape.global_batch, seed=seed + 17))

    rows, losses_by_epoch = [], []
    cur_phase, jitted = 0, None
    for epoch in range(epochs):
        phase = epoch % 2
        if phase != cur_phase:
            state, parked = steps.repartition_state(
                run_cfg.optim, state, parked, phase, mesh=mesh, run=run_cfg,
                schedule=schedule if schedule.active else None,
                boundary=epoch)
            cur_phase = phase
            jitted = None  # ranks may have changed: stale executable
        if jitted is None:
            jitted = jax.jit(functools.partial(train, phase=phase))
        seg_bytes = _partition_bytes(state)
        total_rank = sum(rank_adapt.live_rank_map(state.params).values())
        b, s_len = run_cfg.shape.global_batch, run_cfg.shape.seq_len
        probe = steps.shard_batch(
            {"tokens": np.zeros((b, s_len), np.int32),
             "labels": np.zeros((b, s_len), np.int32)}, mesh)
        sync_b = _sync_bytes(jitted, state, probe, mesh)
        import time as _t
        times, losses = [], []
        for s in range(steps_per_epoch):
            batch = steps.shard_batch(next(data), mesh)
            t0 = _t.perf_counter()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])  # blocks
            if s > 0:  # first step of a segment pays the compile
                times.append(_t.perf_counter() - t0)
            losses.append(loss)
        losses_by_epoch.append(losses)
        rows.append({
            "arch": ARCH, "variant": variant, "epoch": epoch,
            "boundary": epoch, "phase": phase, "total_rank": int(total_rank),
            "us_per_step": float(np.median(times)) * 1e6,
            "trainable_partition_bytes": int(seg_bytes),
            "sync_bytes_per_step": int(sync_b),
            "mean_loss": float(np.mean(losses)),
        })
    final_loss = float(np.mean(losses_by_epoch[-1]))
    return rows, final_loss


def run(seq=32, batch=4, steps_per_epoch=8, epochs=4, decay=0.75, seed=0):
    devs = len(jax.devices())
    mesh = make_host_mesh(devs, 1)
    rows = []
    finals = {}
    for variant, sched in (("fixed", "none"), ("decay", "decay")):
        run_cfg = _build_run(sched, decay, seq, batch,
                             total_steps=epochs * steps_per_epoch)
        vrows, final = _train_variant(variant, run_cfg, mesh, epochs,
                                      steps_per_epoch, seed)
        rows.extend(vrows)
        finals[variant] = final
    for variant, final in finals.items():
        rows.append({"arch": ARCH, "variant": variant, "summary": True,
                     "final_epoch_loss": final,
                     "devices": devs, "decay": decay})
    return rows


def main(smoke: bool = True, **kw):
    rows = run(**kw)
    print("# rank adaptation: variant/epoch, phase, total_rank, us_per_step, "
          "trainable_partition_bytes, sync_bytes_per_step, mean_loss")
    for r in rows:
        if r.get("summary"):
            print(f"{r['variant']}: final_epoch_loss {r['final_epoch_loss']:.4f}")
        else:
            print(f"{r['variant']}/e{r['epoch']},p{r['phase']},"
                  f"r{r['total_rank']},{r['us_per_step']:.0f},"
                  f"{r['trainable_partition_bytes']}B,"
                  f"{r['sync_bytes_per_step']}B,{r['mean_loss']:.4f}")
    if smoke:
        decayed = [r for r in rows
                   if r["variant"] == "decay" and not r.get("summary")]
        sizes = [r["trainable_partition_bytes"] for r in decayed]
        assert all(a > b for a, b in zip(sizes, sizes[1:])), (
            f"trainable-partition bytes must strictly decrease across "
            f"phases under the decay schedule, got {sizes}")
        fixed = next(r["final_epoch_loss"] for r in rows
                     if r.get("summary") and r["variant"] == "fixed")
        adapted = next(r["final_epoch_loss"] for r in rows
                       if r.get("summary") and r["variant"] == "decay")
        rel = abs(adapted - fixed) / max(abs(fixed), 1e-9)
        assert rel <= 0.02, (
            f"rank-adapted final-epoch loss {adapted:.4f} deviates "
            f"{rel:.1%} (> 2%) from fixed-rank {fixed:.4f}")
        print(f"smoke OK: bytes strictly decreasing {sizes}, "
              f"loss delta {rel:.2%} (<= 2%)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance contract (strictly "
                         "decreasing bytes, <=2% loss delta)")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--decay", type=float, default=0.75)
    args = ap.parse_args()
    record("rank_adaptation", main(smoke=args.smoke, epochs=args.epochs,
                                   steps_per_epoch=args.steps_per_epoch,
                                   decay=args.decay))
