"""Paper Fig. 3: sequential vs regular freezing fine-tuning curves.

Claim under test: sequential freezing converges faster and ends slightly
better than regular freezing (every factor gets trained across epochs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freezing
from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import NO_LRD
from benchmarks.table4_vit import VIT_POLICY, _train_step
from repro.data import SyntheticClassification
from repro.models import vit as vit_mod


def run(steps=120, steps_per_epoch=15, batch=16, img=32, patch=8, d=96,
        heads=3, d_ff=384, layers=4, seed=0):
    key = jax.random.PRNGKey(seed)
    dec = Decomposer(NO_LRD, dtype=jnp.float32)
    dense = vit_mod.vit_init(key, dec, num_layers=layers, d=d, heads=heads,
                             d_ff=d_ff, patch=patch, img=img)
    params0 = apply_lrd(dense, VIT_POLICY.with_min_dim(16).with_alpha(1.5))[0]
    step = jax.jit(functools.partial(_train_step, heads=heads, patch=patch),
                   static_argnums=(3,))

    curves = {}
    for mode in ("sequential", "regular"):
        ds = SyntheticClassification(img=img, batch=batch, seed=7)
        params = params0
        losses, accs = [], []
        for i in range(steps):
            epoch = i // steps_per_epoch
            phase = freezing.phase_for_epoch(epoch, mode)
            x, y = ds.next_batch()
            params, loss = step(params, jnp.asarray(x), jnp.asarray(y), phase)
            losses.append(float(loss))
            if (i + 1) % steps_per_epoch == 0:
                xe, ye = ds.eval_batch(96)
                pred = vit_mod.vit_apply(params, jnp.asarray(xe), heads=heads,
                                         patch=patch)
                accs.append(float(jnp.mean(jnp.argmax(pred, -1) == jnp.asarray(ye))))
        curves[mode] = {"loss": losses, "acc": accs}
    return curves


def main(**kw):
    curves = run(**kw)
    print("# Fig 3: epoch, seq_acc, reg_acc, seq_loss, reg_loss")
    seq, reg = curves["sequential"]["acc"], curves["regular"]["acc"]
    sl, rl = curves["sequential"]["loss"], curves["regular"]["loss"]
    per = len(sl) // max(len(seq), 1)
    for e, (a, b) in enumerate(zip(seq, reg)):
        print(f"{e},{a:.3f},{b:.3f},{np.mean(sl[e*per:(e+1)*per]):.4f},"
              f"{np.mean(rl[e*per:(e+1)*per]):.4f}")
    print(f"final: sequential acc {seq[-1]:.3f} loss {np.mean(sl[-per:]):.4f} "
          f"vs regular acc {reg[-1]:.3f} loss {np.mean(rl[-per:]):.4f}")
    return curves


if __name__ == "__main__":
    main()
