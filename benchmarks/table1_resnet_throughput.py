"""Paper Table 1: train/inference throughput of ResNet before/after LRD with
the proposed acceleration methods (Org / LRD / RankOpt / Freeze / Combined).

CPU analogue of the paper's V100 runs: same models, same method ladder, fps
measured as images/sec on small inputs.  The paper's *claims* under test:
  (1) vanilla LRD gives only a small speedup;
  (2) rank optimization enlarges it (train AND inference);
  (3) freezing accelerates train only (inference == LRD);
  (4) combined is the fastest training config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import method_policies, time_fn
from repro.core import freezing
from repro.core.decompose import Decomposer, apply_lrd
from repro.core.policy import NO_LRD, RESNET_DEFAULT
from repro.models import resnet as resnet_mod


def _train_step(params, x, y, variant, phase):
    def loss_fn(p):
        if phase >= 0:
            p = freezing.apply_freeze(p, freezing.freeze_mask(p, phase))
        logits = resnet_mod.resnet_apply(p, x, variant)
        onehot = jax.nn.one_hot(y, logits.shape[-1])
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    return new, loss


def run(variant: str = "resnet50", batch: int = 4, img: int = 32,
        iters: int = 3, alpha: float = 2.0):
    key = jax.random.PRNGKey(0)
    dec = Decomposer(NO_LRD, dtype=jnp.float32)
    dense_params = resnet_mod.resnet_init(key, variant, 10, dec)
    x = jax.random.normal(key, (batch, img, img, 3))
    y = jax.random.randint(key, (batch,), 0, 10)

    rows = []
    base = {}
    for method, (policy, phase) in method_policies(RESNET_DEFAULT, alpha).items():
        params = dense_params if policy is None else apply_lrd(dense_params, policy)[0]
        tr = jax.jit(functools.partial(_train_step, variant=variant, phase=phase))
        inf = jax.jit(functools.partial(resnet_mod.resnet_apply, variant=variant))
        t_train = time_fn(lambda: tr(params, x, y), iters=iters)
        t_inf = time_fn(lambda: inf(params, x), iters=iters)
        fps_t, fps_i = batch / t_train, batch / t_inf
        if method == "org":
            base = {"t": fps_t, "i": fps_i}
        rows.append({
            "method": method,
            "train_fps": fps_t,
            "train_delta_pct": 100 * (fps_t / base["t"] - 1),
            "infer_fps": fps_i,
            "infer_delta_pct": 100 * (fps_i / base["i"] - 1),
        })
    return rows


def main(variant="resnet50", **kw):
    rows = run(variant, **kw)
    print(f"# Table 1 ({variant}):  method, train_fps, dTrain%, infer_fps, dInfer%")
    for r in rows:
        print(f"{variant}/{r['method']},{r['train_fps']:.1f},"
              f"{r['train_delta_pct']:+.1f}%,{r['infer_fps']:.1f},"
              f"{r['infer_delta_pct']:+.1f}%")
    return rows


if __name__ == "__main__":
    main()
