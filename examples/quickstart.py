"""Quickstart: the paper's pipeline end-to-end on a laptop-scale model.

1. build a dense transformer LM (reduced smollm config)
2. apply LRD (SVD, 2x) with rank optimization (Algorithm 1, analytic-tpu)
3. fine-tune with sequential freezing (Algorithm 2)
4. generate text with the serving engine

  PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, OptimConfig, RunConfig, ShapeConfig
from repro.core.freezing import phase_for_epoch
from repro.data import LMBatchIterator
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine


def main():
    cfg = get_smoke_config("smollm-360m")
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train"),
        lrd=LRDConfig(enabled=True, alpha=2.0, rank_quantize=False, min_dim=16,
                      freeze_mode="sequential"),
        dist=DistConfig(fsdp=False, remat="none"),
        optim=OptimConfig(name="sgdm", lr=2e-2, warmup_steps=5, total_steps=60),
    )

    # 1+2. init with the LRD plan applied (Eq.5 ranks; Algorithm-1 guard)
    params, plan = steps.init_params(run)
    print(plan.summary())

    # 3. fine-tune with sequential freezing: one compiled step per phase,
    # state partitioned per phase (frozen factors leave the optimizer)
    mesh = make_host_mesh(1, 1)
    train = steps.build_train_step(run, mesh)
    cur_phase = phase_for_epoch(0, "sequential")
    state, parked = steps.make_train_state(run.optim, params, cur_phase)
    data = iter(LMBatchIterator(cfg.vocab_size, 64, 8))
    fns = {}
    for step in range(60):
        phase = phase_for_epoch(step // 15, "sequential")
        if phase != cur_phase:  # rotate opt moments, repartition params
            state, parked = steps.repartition_state(run.optim, state, parked,
                                                    phase)
            cur_phase = phase
        if phase not in fns:
            fns[phase] = jax.jit(functools.partial(train, phase=phase))
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, m = fns[phase](state, batch)
        if step % 15 == 0:
            print(f"step {step:3d} phase {phase} loss {float(m['loss']):.4f}")
    print(f"final loss {float(m['loss']):.4f}")

    # 4. serve
    engine = ServeEngine(run, state.params, mesh, max_len=96)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16),
                                                dtype=np.int32)
    out = engine.generate(prompts, max_new=8)
    print("generated:", out.tolist())


if __name__ == "__main__":
    main()
