"""Batched serving example: prefill + continuous greedy decode with an
LRD-compressed model (inference acceleration = rank optimization only,
exactly as the paper's Table 1 infer column).

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine


def main():
    cfg = get_smoke_config("qwen2-72b")  # GQA family, reduced dims
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 32, 4, "decode"),
                    lrd=LRDConfig(enabled=True, rank_quantize=False, min_dim=16),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, plan = steps.init_params(run)
    print(plan.summary())
    mesh = make_host_mesh(1, 1)
    engine = ServeEngine(run, params, mesh, max_len=64)

    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg.vocab_size, (4, 24), dtype=np.int32)
    out = engine.generate(prompts, max_new=16)
    print(f"batch {out.shape[0]} x {out.shape[1]} new tokens")
    for row in out:
        print(" ", row.tolist())


if __name__ == "__main__":
    main()
