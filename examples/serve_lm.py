"""Continuous-batching serving example: rank-quantized export + scheduler.

Trains nothing — inits an LRD-compressed model, exports it with serve-time
rank quantization (Algorithm 1 per layer: truncate to the tile-quantized
rank, merge layers that don't pay back to dense), then streams requests
with per-request max_new/eos through the paged-KV scheduler.

  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import DistConfig, LRDConfig, RunConfig, ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_host_mesh
from repro.serving import ServeEngine, export_for_serving


def main():
    cfg = get_smoke_config("qwen2-72b")  # GQA family, reduced dims
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 64, 4, "decode"),
                    lrd=LRDConfig(enabled=True, rank_quantize=False, min_dim=16),
                    dist=DistConfig(fsdp=False, remat="none"))
    params, plan = steps.init_params(run)
    print(plan.summary())

    # serve-time rank quantization: the paper's Algorithm 1 against the
    # machine this example runs on (measured probes)
    params, report = export_for_serving(params, backend="measured",
                                        probe_tokens=4)
    print(report.summary())

    mesh = make_host_mesh(1, 1)
    engine = ServeEngine(run, params, mesh, max_len=64, num_slots=2,
                         prefill_len=32, block_size=8)

    rng = np.random.default_rng(1)
    requests = [{"prompt": rng.integers(0, cfg.vocab_size, int(n), dtype=np.int32),
                 "max_new": int(m)}
                for n, m in [(24, 16), (8, 4), (16, 8), (30, 12)]]
    outs = engine.serve(
        requests,
        on_token=lambda req, tok: print(f"  req {req.rid} += {tok}"))
    for i, row in enumerate(outs):
        print(f"request {i}: {row.tolist()}")
    stats = engine.scheduler.latency_stats()
    print(f"{stats['tok_per_s']:.1f} tok/s, p95 latency "
          f"{stats['p95_latency_s'] * 1e3:.0f}ms, "
          f"{engine.scheduler.decode_compiles} serve_step compile")


if __name__ == "__main__":
    main()
