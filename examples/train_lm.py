"""End-to-end LM training driver demo: ~100M-scale model, a few hundred
steps, with LRD + sequential freezing + checkpoint/resume + straggler
monitoring — the full production loop on CPU.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

(Resumable: re-running continues from the newest checkpoint.)
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    # smollm-360m smoke config is ~0.1M params; to reach the ~100M scale of a
    # real small-LM run on CPU we use the full smollm-360m geometry but a
    # short sequence. Steps/sec will be minutes-scale; default uses smoke.
    sys.argv = [sys.argv[0]]
    return train_mod.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--steps-per-epoch", "50",
        "--global-batch", "16",
        "--seq-len", "128",
        "--lrd", "--lrd-min-dim", "16",
        "--freeze", "sequential",
        "--optimizer", "sgdm", "--lr", "2e-2",
        "--save-every", "100",
        "--ckpt-dir", "runs/example_train",
    ])


if __name__ == "__main__":
    main()
