"""The paper's own experiment, end-to-end: ResNet-50 + LRD 2x + rank
optimization + sequential freezing, fine-tuned on the synthetic
classification set (CIFAR-10 proxy), reporting accuracy per method.

  PYTHONPATH=src python examples/resnet_cifar.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # repo root
from benchmarks import table3_accuracy


def main():
    rows = table3_accuracy.run(variant="resnet50", steps=30, batch=16,
                               sequential=True)
    print("method, accuracy")
    for r in rows:
        print(f"{r['method']},{r['accuracy']:.3f}")


if __name__ == "__main__":
    main()
